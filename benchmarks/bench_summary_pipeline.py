"""§Perf — summary-pipeline hillclimb (the paper's own hot loop, measured
for real on this host):

  iteration 1: eager per-client summary (baseline; retraces every client)
               -> jitted + power-of-two size bucketing (compile once per
               bucket, reuse across the federation and across refresh rounds)
  iteration 2: fleet-scale batched engine (DESIGN.md §4) — stale clients
               stacked into padded [M, N_bucket, ...] buckets, ONE jitted
               vmap dispatch per bucket chunk instead of one per client.
  iteration 3: server-side registry scan (DESIGN.md §5) — per-client
               needs_refresh python loop vs one batched sym-KL over [N, C]
               vs the streaming registry (dense matrices, O(drifted)
               scatter) at 10k-100k simulated clients.

CSV: pipeline/<...>,us_per_call,derived
"""
from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks._record import emit
from repro.core import BatchedSummaryEngine, RefreshPolicy, SummaryRegistry
from repro.stream import StreamingSummaryRegistry
from repro.data.synthetic import DatasetSpec, FederatedDataset, small_spec
from repro.fl.client import timed_summary
from repro.models.cnn import CNNConfig, build_cnn, cnn_apply


def run(num_clients: int = 12, seed: int = 0) -> list:
    """Iteration 1: eager vs jit+bucket, per client (paper Table 2 regime)."""
    spec = DatasetSpec("femnist-like", 2800, 62, (28, 28, 1),
                       avg_samples=109, max_samples=512)
    data = FederatedDataset(spec, seed=seed)
    enc_params = build_cnn(CNNConfig(in_channels=1, feature_dim=64))
    enc_fn = jax.jit(lambda x: cnn_apply(enc_params, x))
    order = np.argsort(data.sizes)
    cids = order[np.linspace(0, len(order) - 1, num_clients).astype(int)]

    rows = []
    for method in ("py", "pxy", "encoder"):
        for variant, jit in (("eager", False), ("jit+bucket", True)):
            times = []
            for i, cid in enumerate(cids):
                feats, labels, valid = data.client_data(int(cid))
                _, _, dt = timed_summary(
                    method, feats, labels, valid, spec.num_classes,
                    encoder_fn=enc_fn, coreset_k=128, bins=16,
                    key=jax.random.PRNGKey(int(cid)), jit=jit)
                if i > 0:
                    times.append(dt)
            rows.append({"name": f"pipeline/{method}/{variant}",
                         "method": method, "variant": variant,
                         "avg_s": float(np.mean(times))})
    return rows


def run_fleet(num_clients: int = 512, methods=("py", "encoder", "pxy"),
              seed: int = 0) -> list:
    """Iteration 2: refresh a whole fleet of stale clients, per-client jit
    loop vs the batched engine — dispatch counts and wall time, with the
    numerical-equivalence check the new test also asserts."""
    spec = small_spec(num_clients=num_clients, num_classes=10, side=12,
                      avg_samples=48)
    data = FederatedDataset(spec, seed=seed)
    enc_params = build_cnn(CNNConfig(in_channels=1, feature_dim=32),
                           jax.random.PRNGKey(7))
    enc_fn = jax.jit(lambda x: cnn_apply(enc_params, x))
    clients = [(c, *data.client_data(c), jax.random.PRNGKey(seed * 7 + c))
               for c in range(num_clients)]

    rows = []
    for method in methods:
        # per-client path: one jitted dispatch per client (timed_summary
        # already excludes compiles via its warm call)
        per_client_s, per_summaries = 0.0, {}
        for c, feats, labels, valid, key in clients:
            s, _, dt = timed_summary(method, feats, labels, valid,
                                     spec.num_classes, encoder_fn=enc_fn,
                                     coreset_k=32, bins=8, key=key)
            per_client_s += dt
            per_summaries[c] = s
        # batched engine: one dispatch per (bucket, chunk)
        engine = BatchedSummaryEngine(
            method, spec.num_classes, encoder_fn=enc_fn, coreset_k=32,
            bins=8, max_batch=64 if method == "pxy" else 256)
        t0 = time.perf_counter()
        results = engine.summarize(clients)
        end_to_end = time.perf_counter() - t0
        equal = all(np.allclose(per_summaries[c], results[c].summary,
                                atol=1e-5) for c in range(num_clients))
        st = engine.stats
        rows.append({
            "method": method, "clients": num_clients,
            "perclient_s": per_client_s, "perclient_dispatches": num_clients,
            "batched_s": st.wall_s, "batched_dispatches": st.dispatches,
            "end_to_end_s": end_to_end, "equal": equal,
        })
    return rows


def run_registry(n: int = 20_000, num_classes: int = 62, dim: int = 64,
                 drift_frac: float = 0.01, seed: int = 0) -> list:
    """Iteration 3: one server round of refresh decisions + state absorption
    at fleet scale — the python-loop scan vs the vectorized dict registry vs
    the streaming registry's batched scan + O(drifted) scatter."""
    rs = np.random.RandomState(seed)
    policy = RefreshPolicy(max_age_rounds=10 ** 6, kl_threshold=0.05)
    dists = rs.dirichlet([0.5] * num_classes, n).astype(np.float32)
    summaries = rs.rand(n, dim).astype(np.float32)

    base = SummaryRegistry(n, policy)
    stream = StreamingSummaryRegistry(n, policy)
    for c in range(n):
        base.update(c, 0, summaries[c], dists[c])
    stream.update_batch(np.arange(n), 0, summaries, dists)

    # low drift: a few % of clients move, the rest stay put
    fresh = dists.copy()
    ids = rs.choice(n, max(1, int(drift_frac * n)), replace=False)
    fresh[ids] = rs.dirichlet([0.5] * num_classes, ids.size) \
        .astype(np.float32)

    t0 = time.perf_counter()
    loop_stale = [c for c in range(n)
                  if base.needs_refresh(c, 1, fresh[c])]
    loop_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec_stale = base.stale_clients(1, fresh)
    vec_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    stream_stale = stream.stale_clients(1, fresh)
    stream.update_batch(stream_stale, 1,
                        summaries[stream_stale], fresh[stream_stale])
    _ = stream.matrix()                      # zero-copy clustering handoff
    stream_s = time.perf_counter() - t0
    assert loop_stale == vec_stale == stream_stale.tolist()
    return [{
        "name": f"pipeline/registry/n{n}", "n": n, "num_classes": num_classes,
        "stale": len(loop_stale), "loop_s": loop_s, "vectorized_s": vec_s,
        "streaming_s": stream_s,
    }]


def main(fast: bool = True):
    rows = run(num_clients=6 if fast else 16)
    by = {}
    for r in rows:
        by[(r["method"], r["variant"])] = r["avg_s"]
        emit(r["name"], us=r["avg_s"] * 1e6)
    for m in ("py", "pxy", "encoder"):
        if (m, "eager") in by and (m, "jit+bucket") in by:
            sp = by[(m, "eager")] / max(by[(m, "jit+bucket")], 1e-9)
            emit(f"pipeline/{m}/speedup", text=f"{sp:.1f}x")

    # fleet scale: the acceptance bar is >=512 clients refreshed with >=5x
    # fewer jitted dispatches than the per-client path, equal summaries
    fleet = run_fleet(num_clients=512,
                      methods=("py", "encoder") if fast
                      else ("py", "encoder", "pxy"))
    for r in fleet:
        m = r["method"]
        emit(f"pipeline/fleet/{m}/perclient",
             us=r["perclient_s"] / r["clients"] * 1e6,
             dispatches=r["perclient_dispatches"])
        emit(f"pipeline/fleet/{m}/batched",
             us=r["batched_s"] / r["clients"] * 1e6,
             dispatches=r["batched_dispatches"])
        disp_ratio = (r["perclient_dispatches"]
                      / max(r["batched_dispatches"], 1))
        emit(f"pipeline/fleet/{m}/dispatch_reduction",
             text=f"{disp_ratio:.1f}x")
        emit(f"pipeline/fleet/{m}/speedup",
             text=f"{r['perclient_s'] / max(r['batched_s'], 1e-9):.1f}x")
        emit(f"pipeline/fleet/{m}/equal", text=str(r["equal"]))

    # registry scan at fleet scale (DESIGN.md §5)
    reg = run_registry(n=20_000 if fast else 100_000)
    for r in reg:
        emit(f"{r['name']}/loop", us=r["loop_s"] * 1e6, n=r["n"],
             stale=r["stale"])
        emit(f"{r['name']}/vectorized", us=r["vectorized_s"] * 1e6,
             text=f"{r['loop_s'] / max(r['vectorized_s'], 1e-9):.1f}x_vs_loop")
        emit(f"{r['name']}/streaming", us=r["streaming_s"] * 1e6,
             text=f"{r['loop_s'] / max(r['streaming_s'], 1e-9):.1f}x_vs_loop "
                  f"(scan + O(drifted) scatter + zero-copy matrix)")
    return rows + fleet + reg


if __name__ == "__main__":
    main(fast=False)
