"""§Perf — summary-pipeline hillclimb (the paper's own hot loop, measured
for real on this host):

  iteration 1: eager per-client summary (baseline; retraces every client)
               -> jitted + power-of-two size bucketing (compile once per
               bucket, reuse across the federation and across refresh rounds)

CSV: pipeline/<method>/<variant>,us_per_call,speedup
"""
from __future__ import annotations

import numpy as np

import jax

from repro.data.synthetic import DatasetSpec, FederatedDataset
from repro.fl.client import timed_summary
from repro.models.cnn import CNNConfig, build_cnn, cnn_apply


def run(num_clients: int = 12, seed: int = 0) -> list:
    spec = DatasetSpec("femnist-like", 2800, 62, (28, 28, 1),
                       avg_samples=109, max_samples=512)
    data = FederatedDataset(spec, seed=seed)
    enc_params = build_cnn(CNNConfig(in_channels=1, feature_dim=64))
    enc_fn = jax.jit(lambda x: cnn_apply(enc_params, x))
    order = np.argsort(data.sizes)
    cids = order[np.linspace(0, len(order) - 1, num_clients).astype(int)]

    rows = []
    for method in ("py", "pxy", "encoder"):
        for variant, jit in (("eager", False), ("jit+bucket", True)):
            times = []
            for i, cid in enumerate(cids):
                feats, labels, valid = data.client_data(int(cid))
                _, _, dt = timed_summary(
                    method, feats, labels, valid, spec.num_classes,
                    encoder_fn=enc_fn, coreset_k=128, bins=16,
                    key=jax.random.PRNGKey(int(cid)), jit=jit)
                if i > 0:
                    times.append(dt)
            rows.append({"name": f"pipeline/{method}/{variant}",
                         "method": method, "variant": variant,
                         "avg_s": float(np.mean(times))})
    return rows


def main(fast: bool = True):
    rows = run(num_clients=6 if fast else 16)
    by = {}
    for r in rows:
        by[(r["method"], r["variant"])] = r["avg_s"]
        print(f"{r['name']},{r['avg_s'] * 1e6:.0f},")
    for m in ("py", "pxy", "encoder"):
        if (m, "eager") in by and (m, "jit+bucket") in by:
            sp = by[(m, "eager")] / max(by[(m, "jit+bucket")], 1e-9)
            print(f"pipeline/{m}/speedup,0,{sp:.1f}x")
    return rows


if __name__ == "__main__":
    main(fast=False)
