"""Check-in front end at fleet scale (DESIGN.md §12).

    PYTHONPATH=src python -m benchmarks.run --only frontend

The §12 claim: because every check-in is answered by an O(1) gather
against the current *immutable* registry snapshot, request-serve cost is
a function of arrival volume M, never of fleet size N — a million-client
registry serves a check-in as fast as a thousand-client one.  This bench
measures that directly, headless (no training loop): a hand-built
snapshot at N clients, the seeded Poisson arrival process over a diurnal
availability mask, and ``CheckinFrontend.serve`` timed wall-clock.

Records (schema 8):

  * ``frontend/serve/N<n>`` — wall us per check-in served, sustained
    check-ins/sec actually processed, and the *modeled* decision-latency
    distribution (p50/p99/p999 of the k-server FIFO) the history and the
    SLO loop see;
  * ``frontend/stall`` — the same round with a blocking-rebuild stall at
    the window start: the tail (p99/p999) must absorb the stall, the
    median must not — blocking rebuilds hurt exactly where §12 says;
  * ``frontend/admission/overload`` — the bounded ingest queue under
    2x oversubscription: offers/sec through ``AdmissionController.plan``
    plus admitted/shed/deferred-served conservation counts.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._record import emit
from repro.obs.metrics import MetricRegistry
from repro.server.admission import AdmissionController
from repro.server.arrivals import ArrivalConfig, ArrivalProcess
from repro.server.frontend import CheckinFrontend
from repro.server.ingest import IngestQueue
from repro.server.snapshot import RegistrySnapshot


def _snapshot(n: int, seed: int) -> RegistrySnapshot:
    """A frozen fleet-scale snapshot with a realistic partial has-mask."""
    rs = np.random.RandomState(seed)
    has = rs.rand(n) < 0.7
    asg = rs.randint(0, 8, n).astype(np.int64)
    has.setflags(write=False)
    asg.setflags(write=False)
    return RegistrySnapshot(version=1, round_idx=0, registry_version=1,
                            assignment=asg, num_clusters=8, has_mask=has)


def bench_serve(n_clients: int, rounds: int, rate: float,
                seed: int = 0) -> dict:
    """Time ``serve`` wall-clock over a multi-round check-in storm."""
    snap = _snapshot(n_clients, seed)
    rs = np.random.RandomState(seed + 1)
    # diurnal-ish availability: ~60% of the fleet reachable
    available = rs.rand(n_clients) < 0.6
    active = available.copy()
    arrivals = ArrivalProcess(ArrivalConfig(rate=rate, window_s=60.0,
                                            seed=seed))
    frontend = CheckinFrontend(workers=4, service_s=50e-6,
                               metrics=MetricRegistry())

    total = 0
    t0 = time.perf_counter()
    last = None
    for rnd in range(rounds):
        sched = arrivals.schedule(rnd, available)
        last = frontend.serve(sched, snap, active)
        total += last.checkins
    wall = time.perf_counter() - t0
    hist = frontend.metrics.histogram("frontend/checkin_latency_s")
    pct = hist.percentiles()
    return {"checkins": total, "wall_s": wall,
            "us_per_checkin": wall / max(total, 1) * 1e6,
            "wall_per_s": total / max(wall, 1e-9),
            "p50_s": pct["p50"], "p99_s": pct["p99"],
            "p999_s": pct["p999"],
            "sustained_per_s": last.sustained_per_s if last else 0.0}


def bench_stall(n_clients: int, seed: int = 0) -> dict:
    """One round served twice — without and with a blocking-rebuild
    stall — to show the stall lands in the tail, not the median."""
    snap = _snapshot(n_clients, seed)
    rs = np.random.RandomState(seed + 2)
    available = rs.rand(n_clients) < 0.6
    arrivals = ArrivalProcess(ArrivalConfig(rate=1.0, window_s=60.0,
                                            seed=seed + 7))
    sched = arrivals.schedule(0, available)
    fe = CheckinFrontend(workers=4, service_s=50e-6)
    clean = fe.serve(sched, snap, available)
    stalled = fe.serve(sched, snap, available, stall_s=2.0)
    return {"checkins": clean.checkins,
            "clean_p50_s": clean.p50_s, "clean_p99_s": clean.p99_s,
            "stall_p50_s": stalled.p50_s, "stall_p99_s": stalled.p99_s,
            "stall_p999_s": stalled.p999_s}


def bench_admission(n_offers: int, max_depth: int, rounds: int,
                    seed: int = 0) -> dict:
    """Bounded ingest queue under sustained 2x oversubscription."""
    rs = np.random.RandomState(seed)
    adm = AdmissionController(max_depth=max_depth, retry_after=1)
    q = IngestQueue(max_depth=max_depth)
    offered = 0
    t0 = time.perf_counter()
    for rnd in range(rounds):
        # like the real driver's scan stage, never re-offer a client
        # whose previous summary is still deferred in admission
        busy = adm.in_flight()
        ids = [int(c) for c in
               rs.choice(10 * n_offers, size=n_offers, replace=False)
               if int(c) not in busy]
        summaries = {int(c): {"kind": "bench"} for c in ids}
        fresh = {int(c): np.zeros(4, np.float32) for c in ids}
        priority = {int(c) for c in ids[: n_offers // 4]}
        decision = adm.plan(rnd, q, summaries, fresh, priority)
        offered += len(summaries)
        for cr, summ, rows in decision.batches:
            q.enqueue(cr, 0, summ, rows, ready_round=rnd)
        # drain what became ready so next round has fresh capacity
        q.pop_ready(rnd)
    wall = time.perf_counter() - t0
    return {"offered": offered, "admitted": adm.admitted_total,
            "shed": adm.shed_total,
            "deferred_served": adm.deferred_served_total,
            "still_deferred": len(adm.in_flight()),
            "us_per_offer": wall / max(offered, 1) * 1e6,
            "offers_per_s": offered / max(wall, 1e-9)}


def main(fast: bool = True, seed: int = 0):
    n = 1_000_000
    rounds = 2 if fast else 4
    rate = 0.5 if fast else 2.0

    r = bench_serve(n, rounds=rounds, rate=rate, seed=seed)
    assert r["p50_s"] <= r["p99_s"] <= r["p999_s"], r
    emit(f"frontend/serve/N{n // 1000}k", us=r["us_per_checkin"],
         checkins=r["checkins"],
         checkins_per_s=f"{r['wall_per_s']:.0f}",
         sustained_per_s=f"{r['sustained_per_s']:.0f}",
         p50_s=f"{r['p50_s']:.6f}", p99_s=f"{r['p99_s']:.6f}",
         p999_s=f"{r['p999_s']:.6f}")

    # O(1)-in-N: the same arrival volume against a 1000x smaller fleet
    # must serve at a comparable per-check-in cost (arrivals scale with
    # the available fleet, so compare us/checkin, not totals)
    r_small = bench_serve(1_000, rounds=rounds, rate=rate, seed=seed)
    emit("frontend/serve/N1k", us=r_small["us_per_checkin"],
         checkins=r_small["checkins"],
         checkins_per_s=f"{r_small['wall_per_s']:.0f}")

    st = bench_stall(n if not fast else 100_000, seed=seed)
    assert st["stall_p99_s"] >= st["clean_p99_s"], st
    emit("frontend/stall", us=0.0,
         checkins=st["checkins"],
         clean_p50_s=f"{st['clean_p50_s']:.6f}",
         clean_p99_s=f"{st['clean_p99_s']:.6f}",
         stall_p50_s=f"{st['stall_p50_s']:.6f}",
         stall_p99_s=f"{st['stall_p99_s']:.6f}",
         stall_p999_s=f"{st['stall_p999_s']:.6f}")

    a = bench_admission(n_offers=2_000 if fast else 20_000,
                        max_depth=1_000 if fast else 10_000,
                        rounds=4, seed=seed)
    # conservation: every offer is admitted, shed (=> deferred), or
    # still waiting; deferred re-offers that landed count once
    assert a["admitted"] + a["still_deferred"] == a["offered"], a
    emit("frontend/admission/overload", us=a["us_per_offer"],
         offered=a["offered"], admitted=a["admitted"], shed=a["shed"],
         deferred_served=a["deferred_served"],
         still_deferred=a["still_deferred"],
         offers_per_s=f"{a['offers_per_s']:.0f}")


if __name__ == "__main__":
    main()
