"""Paper Table 2 (left): per-client distribution-summary time.

Times the three summary methods on synthetic datasets shaped like the
paper's Table 1 (FEMNIST-like 28×28×1/62 classes; OpenImage-like
256×256×3/600 classes).  P(X|y) histograms operate on spatially pooled
features (`pool`) so the baseline fits in container memory — the paper's
>64 GB observation is exactly this term at full resolution; we report the
measured time plus the dimensional extrapolation.

CSV: method,dataset,avg_s,max_s,summary_dim
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._record import emit
from repro.data.synthetic import DatasetSpec, FederatedDataset
from repro.fl.client import timed_summary
from repro.models.cnn import CNNConfig, build_cnn, cnn_apply


def _pool(feats: np.ndarray, factor: int) -> np.ndarray:
    if factor <= 1:
        return feats
    n, h, w, c = feats.shape
    h2, w2 = h // factor, w // factor
    return feats[:, :h2 * factor, :w2 * factor].reshape(
        n, h2, factor, w2, factor, c).mean((2, 4))


def run(num_clients: int = 8, openimage_side: int = 64,
        openimage_clients: int = 11325, coreset_k: int = 128,
        encoder_dim: int = 64, bins: int = 16, pool: int = 2,
        use_kernel: bool = False, seed: int = 0) -> list:
    specs = {
        "femnist": DatasetSpec("femnist-like", 2800, 62, (28, 28, 1),
                               avg_samples=109, max_samples=512),
        # feature side scaled (full 256 documented as extrapolation)
        "openimage": DatasetSpec("openimage-like", openimage_clients, 600,
                                 (openimage_side, openimage_side, 3),
                                 avg_samples=228, max_samples=465),
    }
    rows = []
    for dname, spec in specs.items():
        data = FederatedDataset(spec, seed=seed)
        enc_cfg = CNNConfig(in_channels=spec.feature_shape[-1],
                            feature_dim=encoder_dim)
        enc_params = build_cnn(enc_cfg)
        enc_fn = jax.jit(lambda x: cnn_apply(enc_params, x))
        # pick clients spanning small->large datasets
        order = np.argsort(data.sizes)
        cids = order[np.linspace(0, len(order) - 1, num_clients).astype(int)]
        for method in ("py", "pxy", "encoder"):
            times = []
            dim = 0
            for i, cid in enumerate(cids):
                feats, labels, valid = data.client_data(int(cid))
                if method == "pxy":
                    feats = _pool(feats, pool)
                s, _, dt = timed_summary(
                    method, feats, labels, valid, spec.num_classes,
                    encoder_fn=enc_fn, coreset_k=coreset_k, bins=bins,
                    key=jax.random.PRNGKey(int(cid)),
                    use_kernel=use_kernel)
                if i > 0:            # drop jit-warmup client
                    times.append(dt)
                dim = s.size
            rows.append({
                "name": f"summary/{method}/{dname}",
                "method": method, "dataset": dname,
                "avg_s": float(np.mean(times)), "max_s": float(np.max(times)),
                "summary_dim": int(dim),
            })
    return rows


def main(fast: bool = True):
    rows = run(num_clients=5 if fast else 10,
               openimage_side=32 if fast else 64,
               openimage_clients=2000 if fast else 11325)
    der = {}
    for r in rows:
        emit(r["name"], us=r["avg_s"] * 1e6, max_s=f"{r['max_s']:.4f}",
             dim=r["summary_dim"])
        der[(r["method"], r["dataset"])] = r
    for d in ("femnist", "openimage"):
        if ("pxy", d) in der and ("encoder", d) in der:
            sp = der[("pxy", d)]["max_s"] / max(der[("encoder", d)]["max_s"], 1e-9)
            emit(f"summary/speedup_pxy_over_encoder/{d}",
                 text=f"{sp:.1f}x")
    # paper-scale extrapolation: P(X|y) cost grows linearly in the raw
    # feature dim D (histogram over every dim); the encoder summary is
    # ~constant in D (coreset + fixed CNN).  Fit t = a·D from the two
    # measured scales and evaluate at the paper's full resolutions.
    if ("pxy", "openimage") in der:
        r = der[("pxy", "openimage")]
        # summary_dim = C * D * B  ->  feature dims D actually histogrammed
        d_measured = r["summary_dim"] / (600 * 16)
        t_per_dim = r["max_s"] / max(d_measured, 1)
        d_full = 3 * 256 * 256                       # paper's 3x256x256
        t_full = t_per_dim * d_full
        enc = der[("encoder", "openimage")]["max_s"]
        emit("summary/extrapolated_pxy_fullres_s", text=f"{t_full:.1f}")
        emit("summary/extrapolated_speedup_fullres",
             text=f"{t_full / max(enc, 1e-9):.0f}x"
                  f" (linear-in-D fit; paper measured ~30x on mobile "
                  f"hardware)")
    return rows


if __name__ == "__main__":
    main(fast=False)
