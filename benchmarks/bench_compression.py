"""Paper §5 future work: summary compression vs clustering quality.

Generates a federation with known heterogeneity structure (style groups,
near-IID labels so only feature structure distinguishes clients), computes
the paper's encoder summaries, then clusters under each compression scheme
and reports group purity vs wire size.

CSV: compression/<method>,bytes_per_client,purity
"""
from __future__ import annotations

import numpy as np

import dataclasses
import jax
import jax.numpy as jnp

from benchmarks._record import emit
from repro.core import encoder_summary, kmeans
from repro.core.compression import (
    compressed_bytes, dequantize_summary, jl_project, pca_project,
    quantize_summary,
)
from repro.data.synthetic import FederatedDataset, small_spec
from repro.models.cnn import CNNConfig, build_cnn, cnn_apply


def _purity(assign, truth, k):
    return sum(np.bincount(truth[assign == c]).max()
               for c in range(k) if (assign == c).any()) / len(truth)


def run(num_clients: int = 48, out_dim: int = 32, seed: int = 3) -> list:
    spec = small_spec(num_clients=num_clients, num_classes=6, side=10,
                      avg_samples=60, num_styles=4, alpha=50.0)
    data = FederatedDataset(spec, seed=seed)
    enc = build_cnn(CNNConfig(in_channels=1, feature_dim=16),
                    jax.random.PRNGKey(5))
    enc_fn = jax.jit(lambda x: cnn_apply(enc, x))
    S = []
    for c in range(spec.num_clients):
        feats, labels, valid = (jnp.asarray(a) for a in data.client_data(c))
        S.append(np.asarray(encoder_summary(
            feats, labels, valid, enc_fn, spec.num_classes, 32,
            jax.random.PRNGKey(c))))
    X = jnp.asarray(np.stack(S), jnp.float32)
    n, d = X.shape
    key = jax.random.PRNGKey(0)

    variants = {
        "none": X,
        "int8": dequantize_summary(quantize_summary(X)),
        "jl": jl_project(X, out_dim, key),
        "pca": pca_project(X, out_dim)[0],
        "jl+int8": dequantize_summary(quantize_summary(
            jl_project(X, out_dim, key))),
        "pca+int8": dequantize_summary(quantize_summary(
            pca_project(X, out_dim)[0])),
    }
    rows = []
    truth = data.true_groups()
    for method, Z in variants.items():
        res = kmeans(jnp.asarray(Z, jnp.float32), spec.num_styles,
                     jax.random.PRNGKey(1))
        pur = _purity(np.asarray(res.assignment), truth, spec.num_styles)
        nbytes = compressed_bytes(1, d, method, out_dim)
        rows.append({"name": f"compression/{method}",
                     "method": method, "bytes_per_client": nbytes,
                     "purity": pur})
    return rows


def main(fast: bool = True):
    rows = run(num_clients=32 if fast else 64,
               out_dim=16 if fast else 32)
    base = next(r for r in rows if r["method"] == "none")
    for r in rows:
        ratio = base["bytes_per_client"] / max(r["bytes_per_client"], 1)
        emit(r["name"], bytes=r["bytes_per_client"],
             purity=f"{r['purity']:.2f}", compression=f"{ratio:.0f}x")
    return rows


if __name__ == "__main__":
    main(fast=False)
