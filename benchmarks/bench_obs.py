"""§10 — telemetry overhead: instrumentation must be off the clock.

The paper's headline numbers are *overhead* measurements, so the
telemetry that measures them must not move them.  Three records, two
asserts:

  * **A/B server rounds at fleet scale** — the 100k-client headless
    server loop (``bench_server.run_server``, the paper-scale critical
    path) with the observer disabled (the default null object) vs
    enabled (``obs.observe``).  Arms are interleaved in alternating
    order and each round's floor is the min across repeats, so linear
    machine drift cancels and heavy-tail scheduler noise is clipped.
    Asserted: enabled adds less than ``OVERHEAD_BUDGET`` (2%) *plus the
    measured noise floor* — the disabled arm's own split-half
    disagreement, so a genuinely hot instrumentation path fails the
    gate while container jitter does not.
  * **accounted upper bound** — events-per-round from a real
    ``fl.rounds`` federation run under ``obs.observe`` (hook counts are
    scale-independent) × the measured per-hook cost, charged against
    the fleet-scale per-round critical floor *as if every hook sat on
    the critical path* (it does not: spans open outside the timed
    windows by design).  Even this overestimate must stay under the 2%
    budget — asserted unconditionally; it is deterministic, so it is
    the CI-stable teeth of the gate.
  * **hook microcosts** — per-call cost of a disabled span (the no-op
    everyone pays by default), an enabled span, an instant event, a
    counter inc and a histogram record, so a regression in any hook is
    visible as its own record instead of hiding inside a 2% budget.

The gate covers the PR-10 drill-down surfaces too: the enabled A/B arm
arms the **flight recorder** (``flight_path`` streaming to disk), the
accounted bound charges flight-record appends and labeled-family
writes at their measured per-call costs on top of the span events, and
``obs/labeled/*`` / ``obs/recorder/*`` records expose those costs
individually (the disabled recorder check must stay at one attribute
read).

CSV: ``obs/overhead/critical`` (A/B floors + fractions),
``obs/overhead/accounted`` (the upper bound), ``obs/hook/*``,
``obs/labeled/*`` and ``obs/recorder/*``.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

import repro.api as api
import repro.obs as obs
from benchmarks._record import emit
from benchmarks.bench_server import run_server
from repro.data.synthetic import FederatedDataset, small_spec
from repro.obs.metrics import split_labeled
from repro.obs.recorder import FlightRecorder

OVERHEAD_BUDGET = 0.02     # enabled tracer may add <2% to the critical path
N_CLIENTS = 100_000        # the paper-scale fleet the claim is about


def _critical_rounds(out_dir: str | None, rounds: int,
                     seed: int) -> np.ndarray:
    """One headless server run; per-round critical-path seconds."""
    if out_dir is None:
        r = run_server(N_CLIENTS, "sync", rounds=rounds, seed=seed)
    else:
        with obs.observe(
                trace_path=os.path.join(out_dir, "trace.json"),
                metrics_path=os.path.join(out_dir, "metrics.jsonl"),
                flight_path=os.path.join(out_dir, "flight.jsonl")):
            r = run_server(N_CLIENTS, "sync", rounds=rounds, seed=seed)
    return np.asarray(r["critical_per_round"])


def run_ab(rounds: int = 8, repeats: int = 4, seed: int = 0) -> dict:
    """Disabled-vs-enabled A/B over the fleet-scale server loop."""
    _critical_rounds(None, 3, seed)            # warmup: jit compile etc.
    disabled, enabled = [], []
    with tempfile.TemporaryDirectory() as tmp:
        for i in range(repeats):               # alternate arm order so
            arms = [(disabled, None), (enabled, tmp)]   # slow machine
            for acc, out in (arms if i % 2 == 0 else arms[::-1]):  # drift
                acc.append(_critical_rounds(out, rounds, seed))    # cancels
    dis = np.minimum.reduce(disabled)          # per-round floors
    en = np.minimum.reduce(enabled)
    # the disabled arm's own split-half disagreement is the wall-clock
    # noise this box cannot measure below — the A/B assert budgets it
    half_a = np.minimum.reduce(disabled[0::2]).sum()
    half_b = np.minimum.reduce(disabled[1::2]).sum()
    noise = abs(half_a / max(half_b, 1e-12) - 1.0)
    return {"rounds": rounds, "repeats": repeats,
            "disabled_s": float(dis.sum()), "enabled_s": float(en.sum()),
            "overhead_frac": float(en.sum() / max(dis.sum(), 1e-12) - 1.0),
            "noise_frac": noise}


def _percall(fn, n: int = 20000) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run_hooks() -> dict:
    """Per-call hook costs, both observer states."""
    assert not obs.enabled()
    out = {"span_disabled": _percall(lambda: obs.span("x", round=1))}

    def span_body():
        with obs.span("x", round=1):
            pass
    ob = obs.enable()
    try:
        out["span_enabled"] = _percall(span_body)
        out["instant_enabled"] = _percall(lambda: obs.instant("x", v=1))
        out["counter_inc"] = _percall(
            ob.metrics.counter("bench/hook").inc)
        hist = ob.metrics.histogram("bench/hook_s")
        out["histogram_record"] = _percall(lambda: hist.record(1e-3))
        # labeled-family writes: the hot path is child-cache hit + the
        # underlying instrument write — a get-or-create per call would
        # show up here as a regression
        cfam = ob.metrics.family("bench/labeled", labels=("k",))
        out["labeled_counter_inc"] = _percall(
            lambda: cfam.labeled("a").inc())
        hfam = ob.metrics.family("bench/labeled_s", labels=("k",),
                                 kind="histogram")
        out["labeled_histogram_record"] = _percall(
            lambda: hfam.labeled("a").record(1e-3))
    finally:
        obs.disable()
    # recorder costs: the disabled check every hook site pays (one
    # attribute read off the null object) and an in-memory record append
    out["recorder_disabled"] = _percall(lambda: obs.recorder().enabled)
    rec = FlightRecorder()
    out["recorder_record"] = _percall(
        lambda: rec.record("bench", round=1, n=3, ids=[1, 2, 3]))
    return out


def hooks_per_round(seed: int = 0) -> dict:
    """Telemetry events per round of a fully-hooked *real* federation
    run (async server, staleness refresher, bounded-ingest check-in
    front end, flight recorder armed) — the hook counts are a property
    of the code path, not the fleet size.  Returns per-round rates for
    tracer events, flight-record appends and labeled-family writes."""
    from repro.sim import presets
    data = FederatedDataset(small_spec(num_clients=64, num_classes=5,
                                       side=8, avg_samples=24), seed=seed)
    cfg = api.RunConfig(
        rounds=6, clients_per_round=8, local_steps=1, summary="py",
        refresh_max_age=3, refresh_kl=0.05, eval_every=6, seed=seed,
        registry=api.RegistryConfig(kind="streaming"),
        clustering=api.ClusteringConfig(kind="online", num_clusters=4),
        server=api.ServerConfig(kind="async", refresh="staleness",
                                ingest_delay_rounds=1, snapshot_max_age=2,
                                drift_mass_trigger=0.1,
                                frontend=api.FrontendConfig(
                                    kind="poisson", slo_p99_s=0.002,
                                    ingest_max_depth=8)))
    scen = presets.make_scenario("mobile-churn", 64, seed=seed)
    with obs.observe(flight=True) as ob:
        h = api.run(data, cfg, scenario=scen)
    # labeled writes land in the run's own registry (history metrics);
    # count value/count/writes per child — an overestimate for bulk incs,
    # which only strengthens the accounted upper bound
    labeled = 0.0
    for name, snap in h["metrics"].items():
        if split_labeled(name)[1] is None:
            continue
        labeled += (snap.get("count") or snap.get("writes")
                    or abs(snap.get("value") or 0))
    return {"events": len(ob.tracer.events) / cfg.rounds,
            "flight": len(ob.flight.records) / cfg.rounds,
            "labeled": labeled / cfg.rounds}


def main(fast: bool = True, seed: int = 0):
    ab = run_ab(rounds=8 if fast else 12, seed=seed)
    per_round = (ab["enabled_s"] - ab["disabled_s"]) / ab["rounds"]
    emit("obs/overhead/critical", us=max(per_round, 0.0) * 1e6,
         disabled_s=f"{ab['disabled_s']:.5f}",
         enabled_s=f"{ab['enabled_s']:.5f}",
         overhead_frac=f"{ab['overhead_frac']:.4f}",
         noise_frac=f"{ab['noise_frac']:.4f}",
         budget=f"{OVERHEAD_BUDGET:.2f}", rounds=ab["rounds"],
         n=N_CLIENTS)
    hooks = run_hooks()
    for name, s in hooks.items():
        group = ("obs/labeled" if name.startswith("labeled_")
                 else "obs/recorder" if name.startswith("recorder_")
                 else "obs/hook")
        emit(f"{group}/{name}", us=s * 1e6)
    rates = hooks_per_round(seed=seed)
    # worst-case accounting: every tracer event charged at full
    # enabled-span cost, every flight record at the in-memory append
    # cost, every labeled write at the child-lookup+inc cost — all of it
    # on the critical path
    accounted_s = (rates["events"] * hooks["span_enabled"]
                   + rates["flight"] * hooks["recorder_record"]
                   + rates["labeled"] * hooks["labeled_counter_inc"])
    critical_floor = ab["disabled_s"] / ab["rounds"]
    accounted_frac = accounted_s / max(critical_floor, 1e-12)
    emit("obs/overhead/accounted", us=accounted_s * 1e6,
         events_per_round=f"{rates['events']:.1f}",
         flight_per_round=f"{rates['flight']:.1f}",
         labeled_per_round=f"{rates['labeled']:.1f}",
         accounted_frac=f"{accounted_frac:.5f}",
         budget=f"{OVERHEAD_BUDGET:.2f}")
    # the acceptance gates: enabled telemetry (spans + labeled metrics +
    # flight recorder) stays under 2% of the fleet-scale critical path —
    # deterministically by accounting, and by wall-clock A/B up to this
    # box's measured noise floor
    assert accounted_frac < OVERHEAD_BUDGET, (
        f"accounted telemetry upper bound {accounted_frac:.2%} exceeds the "
        f"{OVERHEAD_BUDGET:.0%} budget ({rates['events']:.0f} events + "
        f"{rates['flight']:.0f} flight records + {rates['labeled']:.0f} "
        f"labeled writes per round vs {critical_floor * 1e3:.2f}ms "
        f"critical)")
    assert ab["overhead_frac"] < OVERHEAD_BUDGET + ab["noise_frac"], (
        f"enabled-tracer A/B overhead {ab['overhead_frac']:.2%} exceeds the "
        f"{OVERHEAD_BUDGET:.0%} budget plus the {ab['noise_frac']:.2%} "
        f"measured noise floor (disabled {ab['disabled_s']:.4f}s, enabled "
        f"{ab['enabled_s']:.4f}s over {ab['rounds']} round floors)")
    return [ab | {"name": "obs/overhead/critical"},
            {"name": "obs/overhead/accounted",
             "accounted_frac": accounted_frac} | rates,
            {"name": "obs/hooks"} | hooks]


if __name__ == "__main__":
    main(fast=False)
